"""Observability is observer-only: ObsSpec telemetry taps leave every
tier's policy decisions and utilities bitwise unchanged, the on-device
accumulators match the host float64 oracle exactly, traces capture the
run lifecycle (including carry-health events) and render as a run
profile, and the shared logging setup keeps default output
print-compatible."""
import json
import os

import numpy as np
import pytest

import repro
from repro.api.run import build_env, build_policy
from repro.api.spec import (EnvSpec, EvalSpec, ExperimentSpec, PolicySpec,
                            TrainSpec)
from repro.experiment.sweep import SimulatedKill, sweep_experiments
from repro.obs import ObsSpec, logging_setup
from repro.obs.report import render_report
from repro.obs.trace import export_perfetto

HORIZON, EVERY = 16, 4
SEEDS = (0, 1)


def _spec(policy="COCS", backend="auto", train=True, telemetry=False,
          trace=None, perfetto=None, horizon=HORIZON, lr=None,
          health="off", checkpoint_dir=None, resume=False,
          aggregator="mean"):
    overrides = (("lr", lr),) if lr is not None else ()
    return ExperimentSpec(
        env=EnvSpec(scenario="paper", backend=backend, overrides=overrides),
        policy=PolicySpec(name=policy),
        train=(TrainSpec(model="logreg", aggregator=aggregator)
               if train else None),
        eval=EvalSpec(eval_every=EVERY, checkpoint_dir=checkpoint_dir,
                      resume=resume, health=health),
        obs=ObsSpec(telemetry=telemetry, trace=trace, perfetto=perfetto),
        horizon=horizon, seeds=SEEDS)


def _assert_same_decisions(a, b):
    np.testing.assert_array_equal(a.selections, b.selections)
    np.testing.assert_array_equal(a.utilities, b.utilities)
    np.testing.assert_array_equal(a.explored, b.explored)
    np.testing.assert_array_equal(a.participants, b.participants)
    if a.accuracy is not None or b.accuracy is not None:
        np.testing.assert_array_equal(a.accuracy, b.accuracy)
        np.testing.assert_array_equal(a.loss, b.loss)


@pytest.fixture(scope="module")
def fused_off():
    return repro.run(_spec())


@pytest.fixture(scope="module")
def fused_on():
    return repro.run(_spec(telemetry=True))


# -- bitwise neutrality, all four tiers ---------------------------------------


def test_tier1_bandit_neutral():
    off = repro.run(_spec(train=False))
    on = repro.run(_spec(train=False, telemetry=True))
    _assert_same_decisions(off, on)
    assert off.tier == on.tier == 1
    # bandit scans carry no taps: telemetry stays None, never fake data
    assert on.telemetry is None


def test_tier2_host_loop_neutral():
    off = repro.run(_spec(policy="CUCB"))
    on = repro.run(_spec(policy="CUCB", telemetry=True))
    _assert_same_decisions(off, on)
    assert off.tier == on.tier == 2
    assert on.telemetry is None


def test_tier3_fused_neutral(fused_off, fused_on):
    _assert_same_decisions(fused_off, fused_on)
    assert fused_off.tier == fused_on.tier == 3
    assert fused_off.telemetry is None
    assert fused_on.telemetry is not None


def test_tier4_device_env_neutral():
    off = repro.run(_spec(backend="device"))
    on = repro.run(_spec(backend="device", telemetry=True))
    _assert_same_decisions(off, on)
    assert off.tier == on.tier == 4
    assert on.telemetry is not None


# -- tap correctness vs the host oracle ---------------------------------------


def test_taps_match_host_oracle(fused_on):
    """Per-round selected/arrived/deadline-miss counts accumulated on
    device must equal the host-side float64 oracle computed from the
    run's own outputs."""
    t = fused_on.telemetry
    series, totals = t["series"], t["totals"]
    sel_oracle = (np.asarray(fused_on.selections) >= 0).sum(axis=2)
    np.testing.assert_array_equal(series["selected"],
                                  sel_oracle.astype(np.float64))
    np.testing.assert_array_equal(series["arrived"],
                                  np.asarray(fused_on.participants,
                                             np.float64))
    # fault-free run: every selected client either arrives or misses
    np.testing.assert_array_equal(
        series["deadline_miss"], series["selected"] - series["arrived"])
    # carried totals == series sums (accumulator threaded across blocks)
    for key in ("selected", "arrived", "deadline_miss"):
        np.testing.assert_allclose(totals[key], series[key].sum(axis=1))
    np.testing.assert_allclose(totals["explored"],
                               np.asarray(fused_on.explored).sum(axis=1))
    assert t["summary"]["rounds"] == HORIZON
    assert t["summary"]["participants_per_round"] == pytest.approx(
        np.asarray(fused_on.participants).mean())
    # UCB width is a confidence radius in [0, 1] that shrinks over time
    width = series["ucb_width"].mean(axis=0)
    assert np.all(width >= 0) and np.all(width <= 1)
    assert width[-1] < width[0]


def test_aggregator_adjusted_counts_trims():
    res = repro.run(_spec(telemetry=True, aggregator="trimmed_mean"))
    adj = res.telemetry["series"]["agg_adjusted"]
    assert np.all(adj >= 0)
    assert adj.sum() > 0            # cohorts of >= 3 slots get trimmed
    assert res.telemetry["summary"]["mean_agg_adjusted"] > 0


# -- tracing + report ----------------------------------------------------------


def test_trace_and_report(tmp_path, fused_off):
    trace = str(tmp_path / "run.jsonl")
    pft = str(tmp_path / "run.trace.json")
    res = repro.run(_spec(telemetry=True, trace=trace, perfetto=pft))
    _assert_same_decisions(fused_off, res)      # tracing never perturbs
    recs = [json.loads(ln) for ln in open(trace)]
    names = {r["name"] for r in recs}
    assert {"run.resolve", "run.dispatch", "env.realize", "train.prepare",
            "fused_block", "telemetry"} <= names
    blocks = [r for r in recs if r["name"] == "fused_block"]
    assert len(blocks) == HORIZON // EVERY
    for b in blocks:
        assert b["dur_us"] >= 0 and {"compiled", "factory_hit",
                                     "dispatch_us",
                                     "execute_us"} <= set(b)
    report = render_report(trace)
    assert "## Phase times" in report
    assert "## Fused blocks" in report
    assert "fused_block" in report
    assert "## Telemetry — COCS" in report
    assert "participation / round" in report
    # perfetto export written on tracer close, loadable trace_event JSON
    with open(pft) as f:
        pf = json.load(f)
    assert len(pf["traceEvents"]) == len(recs) - 1      # minus header
    assert export_perfetto(trace, str(tmp_path / "again.json")) > 0


def test_report_rejects_non_trace_input(tmp_path):
    """A ledger/arbitrary file is refused with a named error, not a
    raw traceback (the CLI renders it as `error: ...`, exit 2)."""
    p = tmp_path / "ledger.json"
    p.write_text('[{"name": "x"}]\n')
    with pytest.raises(ValueError, match="not a repro JSONL trace"):
        render_report(str(p))
    with pytest.raises(ValueError, match="not a repro JSONL trace"):
        export_perfetto(str(p), str(tmp_path / "out.json"))


def test_health_events_reach_the_trace(tmp_path):
    """PR 8's carry-guard findings must appear in the JSONL stream, not
    only in RunResult.health."""
    trace = str(tmp_path / "bad.jsonl")
    res = repro.run(_spec(horizon=8, lr=float("nan"), health="record",
                          trace=trace))
    assert len(res.health["events"]) == 2
    health = [json.loads(ln) for ln in open(trace)
              if json.loads(ln).get("name") == "health"]
    assert len(health) == 2
    assert health[0]["round_end"] == 4
    assert any("edge" in leaf for leaf in health[0]["bad"])
    assert "Health events" in render_report(trace)


# -- checkpoint/resume interplay ----------------------------------------------


def test_kill_resume_with_telemetry_bitwise(tmp_path, fused_on):
    """A killed telemetry run resumes bitwise — including the telemetry
    series/totals, whose accumulator rides the checkpointed carry."""
    ck = str(tmp_path / "ck")
    spec = _spec(telemetry=True)
    env = build_env(spec.env)
    pol = build_policy(spec.policy, env.cfg, spec.horizon)
    with pytest.raises(SimulatedKill):
        sweep_experiments({spec.policy.name: pol}, env, list(spec.seeds),
                          spec.horizon, eval_every=EVERY,
                          checkpoint_dir=ck, telemetry=True,
                          stop_after_blocks=2)
    resumed = repro.run(_spec(telemetry=True, checkpoint_dir=ck,
                              resume=True))
    _assert_same_decisions(fused_on, resumed)
    for key, val in fused_on.telemetry["series"].items():
        np.testing.assert_array_equal(val, resumed.telemetry["series"][key],
                                      err_msg=key)
    for key, val in fused_on.telemetry["totals"].items():
        np.testing.assert_allclose(val, resumed.telemetry["totals"][key],
                                   err_msg=key)


def test_resume_refuses_cross_telemetry_mode(tmp_path):
    """A telemetry-on checkpoint is a different run shape than the
    telemetry-off one — resuming across modes must be refused."""
    ck = str(tmp_path / "ck")
    spec = _spec(telemetry=True)
    env = build_env(spec.env)
    pol = build_policy(spec.policy, env.cfg, spec.horizon)
    with pytest.raises(SimulatedKill):
        sweep_experiments({spec.policy.name: pol}, env, list(spec.seeds),
                          spec.horizon, eval_every=EVERY,
                          checkpoint_dir=ck, telemetry=True,
                          stop_after_blocks=1)
    with pytest.raises(ValueError, match="different run"):
        repro.run(_spec(checkpoint_dir=ck, resume=True))


# -- ObsSpec -------------------------------------------------------------------


def test_obsspec_round_trip():
    spec = _spec(telemetry=True, trace="/tmp/x.jsonl")
    back = ExperimentSpec.from_dict(spec.to_dict())
    assert back == spec
    assert back.obs.telemetry is True and back.obs.trace == "/tmp/x.jsonl"
    # default obs round-trips too (and stays disabled)
    plain = _spec()
    assert ExperimentSpec.from_dict(plain.to_dict()).obs == ObsSpec()
    assert not ObsSpec().enabled and spec.obs.enabled


def test_obsspec_rejects_perfetto_without_trace():
    with pytest.raises(ValueError, match="perfetto"):
        ObsSpec(perfetto="/tmp/out.json")


def test_trial_record_telemetry_rides_outside_metrics():
    from repro.trials.metrics import TrialRecord, record_from_entry
    rec = TrialRecord(suite="s", policy="COCS", coord=(),
                      cum_utility=1.0, cum_utility_seeds=(1.0,),
                      participation=2.0,
                      telemetry={"deadline_miss_rate": 0.25})
    entry = rec.to_entry()
    assert entry["telemetry"] == {"deadline_miss_rate": 0.25}
    assert "deadline_miss_rate" not in entry["metrics"]
    assert record_from_entry(entry).telemetry == rec.telemetry


# -- logging setup -------------------------------------------------------------


def test_logging_default_is_print_compatible(capfd):
    log = logging_setup.setup()
    log.info("name,123.4,derived=ok")
    out, err = capfd.readouterr()
    assert out == "name,123.4,derived=ok\n"
    assert err == ""


def test_progress_lines_go_to_stderr(capfd):
    logging_setup.setup()
    logging_setup.get_logger("repro.progress").info("[suite] 1/4 COCS")
    out, err = capfd.readouterr()
    assert out == ""
    assert "[suite] 1/4 COCS" in err


def test_quiet_drops_info_keeps_warnings(capfd):
    try:
        log = logging_setup.setup(quiet=True)
        log.info("hidden")
        log.warning("shown")
        out, _ = capfd.readouterr()
        assert "hidden" not in out and "shown" in out
    finally:
        logging_setup.setup()       # restore defaults for other tests


def test_env_var_zero_code_capture(tmp_path, monkeypatch):
    """REPRO_TRACE activates the global tracer without any code change
    (the CI benchmark step's capture path)."""
    import repro.obs.trace as tr
    trace = str(tmp_path / "env.jsonl")
    monkeypatch.setattr(tr, "_TRACER", None)
    monkeypatch.setattr(tr, "_ENV_CHECKED", False)
    monkeypatch.setenv("REPRO_TRACE", trace)
    try:
        assert tr.active() is not None
        with tr.span("unit", k=1):
            pass
        tr.event("mark", n=2)
        tr._close_global()
    finally:
        monkeypatch.setattr(tr, "_ENV_CHECKED", True)
    recs = [json.loads(ln) for ln in open(trace)]
    assert [r["name"] for r in recs] == ["repro-trace/v1", "unit", "mark"]
    assert recs[1]["k"] == 1 and recs[2]["n"] == 2
    assert os.path.getsize(trace) > 0
