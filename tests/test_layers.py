"""Shared-layer math: chunked recurrence (hypothesis sweep), GQA attention,
norms, rope."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2), h=st.integers(1, 3),
    nc=st.integers(1, 4), chunk=st.sampled_from([8, 16]),
    dk=st.sampled_from([4, 16]), dv=st.sampled_from([4, 24]),
    exclusive=st.booleans(), with_init=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_chunked_recurrence_matches_scan(b, h, nc, chunk, dk, dv, exclusive,
                                         with_init, seed):
    t = nc * chunk
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, h, t, dk))
    k = jax.random.normal(ks[1], (b, h, t, dk))
    v = jax.random.normal(ks[2], (b, h, t, dv))
    lw = -jnp.abs(jax.random.normal(ks[3], (b, h, t, dk))) * 0.2
    u = jax.random.normal(ks[4], (h, dk)) * 0.3 if exclusive else None
    s0 = (jax.random.normal(ks[5], (b, h, dk, dv)) * 0.2
          if with_init else None)
    y1, f1 = L.chunked_linear_recurrence(r, k, v, lw, chunk=chunk, u=u,
                                         init_state=s0)
    y2, f2 = L.linear_recurrence_ref(r, k, v, lw, u=u, init_state=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=1e-3, rtol=1e-3)


def test_recurrence_step_composes_with_chunked():
    """Running decode steps after a chunked prefix == chunked on the whole."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    b, h, t, dk, dv = 1, 2, 32, 8, 8
    r = jax.random.normal(ks[0], (b, h, t, dk))
    k = jax.random.normal(ks[1], (b, h, t, dk))
    v = jax.random.normal(ks[2], (b, h, t, dv))
    lw = -jnp.abs(jax.random.normal(ks[3], (b, h, t, dk))) * 0.2
    u = jax.random.normal(ks[4], (h, dk)) * 0.3
    y_all, _ = L.chunked_linear_recurrence(r, k, v, lw, chunk=8, u=u)
    half = t // 2
    _, s_half = L.chunked_linear_recurrence(
        r[:, :, :half], k[:, :, :half], v[:, :, :half], lw[:, :, :half],
        chunk=8, u=u)
    s = s_half
    for i in range(half, t):
        y_i, s = L.linear_recurrence_step(r[:, :, i], k[:, :, i],
                                          v[:, :, i], lw[:, :, i], s, u=u)
        np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_all[:, :, i]),
                                   atol=1e-4, rtol=1e-4)


def test_gqa_attention_equals_mha_when_kv_equals_h():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    b, s, h, d = 2, 16, 4, 8
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    pos = jnp.arange(s)
    mask = L.attention_scores_mask(pos, pos)
    out = L.gqa_attention(q, k, v, mask)
    # naive reference
    import math
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) / math.sqrt(d)
    scores = scores + mask[None, None]
    ref = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_mask_semantics():
    pos = jnp.arange(6)
    m = L.attention_scores_mask(pos, pos)
    assert m.shape == (6, 6)
    assert (np.asarray(m)[np.triu_indices(6, 1)] < -1e29).all()
    m2 = L.attention_scores_mask(pos, pos, sliding_window=2)
    assert m2[3, 1] < -1e29 and m2[3, 2] == 0.0
    m3 = L.attention_scores_mask(pos, pos, prefix_len=3)
    assert m3[0, 2] == 0.0  # prefix fully visible


def test_rope_orthogonality():
    """RoPE preserves norms and relative-position property."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rms_norm_zero_mean_scale():
    x = jnp.array([[3.0, -4.0]])
    w = jnp.zeros(2)
    y = L.rms_norm(x, w)
    np.testing.assert_allclose(np.mean(np.square(np.asarray(y))), 1.0,
                               rtol=1e-4)
