"""Sharding rules + roofline HLO parsing (no multi-device compile here; the
512-device lowering is exercised by repro.launch.dryrun)."""
import numpy as np
import pytest

from repro.roofline.analysis import (_shape_bytes, collective_bytes_from_hlo,
                                     roofline_report)


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[10]") == 10


def test_collective_parsing():
    hlo = """
  %ag = f32[32,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(%z), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%w)
  %a2a = f32[4]{0} all-to-all(%v)
  %ags = f32[2,2]{1,0} all-gather-start(%q)
  %agd = f32[2,2]{1,0} all-gather-done(%ags)
  %not_a_coll = f32[999,999]{1,0} add(%a, %b)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 32 * 128 * 4 + 2 * 2 * 4  # incl -start only
    assert out["all-reduce"] == 64 * 2
    assert out["reduce-scatter"] == 16 * 4
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["all-to-all"] == 16


def test_roofline_dominant_term():
    r = roofline_report(flops_per_device=197e12, bytes_per_device=0.0,
                        collective_bytes_per_device=0.0, chips=4)
    assert r["dominant"] == "compute_s"
    assert r["compute_s"] == pytest.approx(1.0)
    r2 = roofline_report(1.0, 819e9, 0.0, chips=4, model_flops=2.0)
    assert r2["dominant"] == "memory_s"
    assert r2["useful_flops_frac"] == pytest.approx(0.5)


def test_param_spec_rules():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_spec
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # pattern rule hits with divisibility (mesh axes of size 1 divide all)
    assert param_spec("layers/attn/wq", (28, 64, 64), mesh) == \
        P(None, "data", "model")
    assert param_spec("layers/moe/w_gate", (28, 8, 64, 32), mesh) == \
        P(None, "model", "data", None)
    assert param_spec("embed", (100, 64), mesh) == P("model", "data")


def test_param_spec_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_spec
    # fake a (1, 2)-ish logical mesh using a reshaped single device is not
    # possible; instead check the pure helper on a mesh dict via monkeypatch
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = param_spec("layers/attn/wq", (28, 100, 96), FakeMesh())
    # 100 % 16 != 0 -> pattern fails -> greedy: 96 divisible -> model
    assert spec == P(None, None, "model")
