"""Transposed local-SGD GEMM layout (``TrainSpec(transposed_gemm=True)``):
parity against the default layout at every level."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import api
from repro.fed.client import local_sgd
from repro.models.logistic import make_loss_fn, make_model


def test_local_sgd_delta_parity():
    """Same zeros init, same batches: the transposed layout's deltas are
    exactly the transpose of the default layout's."""
    key = jax.random.PRNGKey(0)
    p, _ = make_model("logreg", key, input_shape=(784,))
    pt, logits_t = make_model("logreg-t", key, input_shape=(784,))
    assert pt["wt"].shape == (10, 784)
    rng = np.random.default_rng(3)
    batches = {
        "x": jnp.asarray(rng.standard_normal((4, 16, 784)), jnp.float32),
        "y": jnp.asarray(rng.integers(0, 10, (4, 16))),
    }
    d, loss = local_sgd(p, make_loss_fn("logreg"), batches, 0.01)
    dt, loss_t = local_sgd(pt, make_loss_fn("logreg-t"), batches, 0.01)
    np.testing.assert_allclose(np.asarray(d["w"]).T, np.asarray(dt["wt"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(d["b"]), np.asarray(dt["b"]),
                               atol=1e-6)
    assert float(loss) == pytest.approx(float(loss_t), abs=1e-6)


def test_fused_sweep_layout_parity():
    """End-to-end through the fused tier: identical policy decisions and
    matching training metrics between layouts."""
    spec = api.ExperimentSpec(policy=api.PolicySpec("cocs"),
                              env=api.EnvSpec("paper"),
                              train=api.TrainSpec(),
                              eval=api.EvalSpec(4), horizon=8, seeds=(0,))
    spec_t = dc.replace(spec,
                        train=api.TrainSpec(transposed_gemm=True))
    assert spec_t.train.model_kind == "logreg-t"
    res, res_t = repro.run(spec), repro.run(spec_t)
    np.testing.assert_array_equal(res.selections, res_t.selections)
    np.testing.assert_allclose(res.accuracy, res_t.accuracy, atol=1e-4)
    np.testing.assert_allclose(res.loss, res_t.loss, atol=1e-4)
