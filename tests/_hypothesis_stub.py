"""Minimal offline fallback for the `hypothesis` API surface these tests
use (given / settings / strategies.integers / sampled_from / booleans).

Installed into ``sys.modules['hypothesis']`` by conftest.py ONLY when the
real package is unavailable (this container has no network access). Each
decorated test runs ``max_examples`` times with draws from a fixed-seed
RNG, so failures are reproducible; the real hypothesis package — declared
in pyproject's test extra — takes precedence whenever it is installed.
"""
from __future__ import annotations

import types

import numpy as np

__version__ = "0.0-repro-stub"


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _floats(min_value=0.0, max_value=1.0, **_ignored):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


strategies = types.SimpleNamespace(integers=_integers,
                                   sampled_from=_sampled_from,
                                   booleans=_booleans,
                                   floats=_floats)

_DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NB: not functools.wraps — the wrapper must present a zero-arg
        # signature or pytest would treat the strategy params as fixtures
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_settings",
                        {}).get("max_examples", _DEFAULT_MAX_EXAMPLES)
            for example in range(n):
                rng = np.random.default_rng(1_000_003 * example + 17)
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 — re-raise with context
                    raise AssertionError(
                        f"falsifying example #{example}: {drawn!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
