"""P2/P3 solvers: feasibility invariants (hypothesis) + optimality vs
brute force on small instances."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.selection import (SelectionProblem, brute_force_select,
                                  check_feasible, flgreedy_select,
                                  greedy_select, max_cardinality_select,
                                  selection_utility)


def random_problem(rng, n, m, budget=None):
    values = rng.uniform(0, 1, (n, m))
    costs = rng.uniform(0.2, 1.0, n)
    budgets = np.full(m, budget if budget is not None
                      else rng.uniform(0.5, 2.0))
    eligible = rng.uniform(size=(n, m)) < 0.7
    return SelectionProblem(values, costs, budgets, eligible)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 12),
       m=st.integers(1, 4))
def test_greedy_always_feasible(seed, n, m):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n, m)
    assert check_feasible(prob, greedy_select(prob))


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 12),
       m=st.integers(1, 4))
def test_flgreedy_always_feasible(seed, n, m):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n, m)
    assert check_feasible(prob, flgreedy_select(prob))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 10),
       m=st.integers(1, 3))
def test_max_cardinality_feasible(seed, n, m):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n, m)
    mask = rng.uniform(size=(n, m)) < 0.5
    assert check_feasible(prob, max_cardinality_select(prob, mask))


@pytest.mark.parametrize("seed", range(10))
def test_greedy_near_optimal_small(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, 7, 2)
    opt_assign, opt = brute_force_select(prob)
    g = selection_utility(prob, greedy_select(prob))
    assert g >= 0.5 * opt - 1e-9, (g, opt)


@pytest.mark.parametrize("seed", range(10))
def test_flgreedy_approximation_guarantee(seed):
    """Lemma 3: FLGreedy >= opt / ((1+eps)(2+2M)) for the sqrt utility."""
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, 7, 2)
    _, opt = brute_force_select(prob, sqrt_utility=True)
    v = selection_utility(prob, flgreedy_select(prob), sqrt_utility=True)
    m = prob.m
    assert v >= opt / ((1 + 0.3) * (2 + 2 * m)) - 1e-9


def test_brute_force_respects_budget():
    rng = np.random.default_rng(3)
    prob = random_problem(rng, 6, 2, budget=0.5)
    assign, _ = brute_force_select(prob)
    assert check_feasible(prob, assign)


def test_utility_counts_selected_outcomes():
    values = np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]])
    prob = SelectionProblem(values, np.ones(3), np.array([10.0, 10.0]),
                            np.ones((3, 2), bool))
    assign = np.array([0, 1, -1])
    assert selection_utility(prob, assign) == 2.0
    outcomes = np.zeros((3, 2))
    assert selection_utility(prob, assign, outcomes=outcomes) == 0.0
