"""Batched HFL backend: parity with the legacy per-client loop, Eq. 6
slot-mask semantics over padded capacity, and the stacked aggregation
kernel/ref/edge agreement."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.paper_hfl import MNIST_CONVEX
from repro.data.federated import FederatedDataset
from repro.fed.client import local_sgd, local_sgd_multi
from repro.fed.edge import deadline_masked_aggregate, effective_mask_multi
from repro.fed.hfl import HFLSimConfig, HFLSimulation
from repro.kernels.masked_aggregate.ops import masked_aggregate_stacked
from repro.models.logistic import make_loss_fn

EXP = dc.replace(MNIST_CONVEX, lr=0.05)
ROUNDS = 12


def _data():
    return FederatedDataset.synthetic(EXP.num_clients, kind="mnist", seed=0)


def _run(backend, data, sampler="device"):
    cfg = HFLSimConfig(exp=EXP, rounds=ROUNDS, eval_every=3, seed=0,
                       backend=backend, sampler=sampler)
    sim = HFLSimulation(cfg, "cocs", data=data)
    hist = sim.run()
    return sim, hist


def test_backend_parity_host_sampler():
    """Same numpy batch stream -> batched must reproduce legacy exactly:
    identical policy decisions/participants, edge params to float tolerance,
    accuracy within 1e-3."""
    data = _data()
    sim_l, h_l = _run("legacy", data)
    sim_b, h_b = _run("batched", data, sampler="host")
    assert h_l.rounds == h_b.rounds
    assert h_l.participants == h_b.participants
    np.testing.assert_allclose(h_l.accuracy, h_b.accuracy, atol=1e-3)
    for a, b in zip(jax.tree.leaves(sim_l.edge_params),
                    jax.tree.leaves(sim_b.edge_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_backend_parity_device_sampler():
    """On-device jax.random sampling: policy decisions and participant
    counts stay bitwise identical (selection never depends on batches);
    the learning curve stays close."""
    data = _data()
    _, h_l = _run("legacy", data)
    _, h_b = _run("batched", data)
    assert h_l.rounds == h_b.rounds
    assert h_l.participants == h_b.participants
    np.testing.assert_allclose(h_l.accuracy, h_b.accuracy, atol=0.1)


def test_device_sampler_block_boundary_independence():
    """run() (scan blocks) and round()-by-round (blocks of 1) must produce
    identical results: device sampling keys depend only on (round, slot),
    never on block length or padded slot capacity."""
    data = _data()
    cfg = HFLSimConfig(exp=EXP, rounds=6, eval_every=3, seed=0,
                       backend="batched")
    sim_blocks = HFLSimulation(cfg, "oracle", data=data)
    sim_blocks.run()
    sim_single = HFLSimulation(cfg, "oracle", data=data)
    for t in range(cfg.rounds):
        sim_single.round(t)
    for a, b in zip(jax.tree.leaves(sim_blocks.edge_params),
                    jax.tree.leaves(sim_single.edge_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_batched_round_api():
    """Public per-round API works on the batched backend."""
    data = _data()
    cfg = HFLSimConfig(exp=EXP, rounds=4, eval_every=2, seed=0,
                       backend="batched")
    sim = HFLSimulation(cfg, "oracle", data=data)
    shapes = [a.shape for a in jax.tree.leaves(sim.edge_params)]
    info = sim.round(0)
    assert info["participants"] >= 0.0
    assert [a.shape for a in jax.tree.leaves(sim.edge_params)] == shapes


def test_unknown_backend_rejected():
    cfg = HFLSimConfig(exp=EXP, rounds=2, backend="warp-drive")
    with pytest.raises(ValueError):
        HFLSimulation(cfg, "oracle")


def test_local_sgd_multi_per_client_params():
    """vmap with a leading params axis == looping local_sgd per client."""
    loss_fn = make_loss_fn("logreg")
    key = jax.random.PRNGKey(1)
    n, steps, b, d = 3, 2, 4, 8
    xb = jax.random.normal(key, (n, steps, b, d))
    yb = jax.random.randint(key, (n, steps, b), 0, 10)
    params = [{"w": jax.random.normal(jax.random.fold_in(key, i), (d, 10)),
               "b": jnp.zeros((10,))} for i in range(n)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    deltas, losses = local_sgd_multi(stacked, loss_fn,
                                     {"x": xb, "y": yb}, 0.1,
                                     per_client_params=True)
    for i in range(n):
        di, li = local_sgd(params[i], loss_fn,
                           {"x": xb[i], "y": yb[i]}, 0.1)
        np.testing.assert_allclose(np.asarray(deltas["w"][i]),
                                   np.asarray(di["w"]), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(float(losses[i]), float(li), rtol=1e-5)


def _random_case(rng, m, s, z_min):
    params = {"w": jnp.asarray(rng.standard_normal((m, 6)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((m, 2)), jnp.float32)}
    deltas = {"w": jnp.asarray(rng.standard_normal((m, s, 6)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((m, s, 2)), jnp.float32)}
    n_valid = rng.integers(0, s + 1, m)          # some ESs may be empty
    valid = np.zeros((m, s), np.float32)
    for j in range(m):
        valid[j, :n_valid[j]] = 1.0
    arrived = (rng.random((m, s)) < 0.6).astype(np.float32) * valid
    tau = np.where(valid > 0, rng.random((m, s)).astype(np.float32) * 5.0,
                   np.inf)
    return params, deltas, jnp.asarray(valid), jnp.asarray(arrived), \
        jnp.asarray(tau), n_valid


@settings(max_examples=20)
@given(seed=st.integers(0, 10_000), z_min=st.integers(1, 3),
       s=st.integers(1, 6))
def test_padded_slots_contribute_zero(seed, z_min, s):
    """Property: padded/empty slots never contribute — garbage in the padded
    delta slots cannot change the result, empty ESs keep their params, and
    each ES matches the legacy single-ES aggregation over its real slots."""
    rng = np.random.default_rng(seed)
    m = 3
    params, deltas, valid, arrived, tau, n_valid = _random_case(
        rng, m, s, z_min)
    w = effective_mask_multi(arrived, tau, valid, z_min)
    out = masked_aggregate_stacked(params, deltas, w)
    # 1) garbage-independence: rewrite padded slots with different garbage
    deltas_garbage = jax.tree.map(
        lambda d: jnp.where(valid[..., None] > 0, d, 1e6), deltas)
    out_garbage = masked_aggregate_stacked(params, deltas_garbage, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out_garbage)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for j in range(m):
        pj = jax.tree.map(lambda a: a[j], params)
        c = int(n_valid[j])
        if c == 0:
            # 2) empty ES -> params unchanged
            for a, b in zip(jax.tree.leaves(jax.tree.map(lambda o: o[j],
                                                         out)),
                            jax.tree.leaves(pj)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)
            continue
        # 3) per-ES parity with the legacy path over the real slots only
        dj = jax.tree.map(lambda d: d[j, :c], deltas)
        legacy_out, _ = deadline_masked_aggregate(
            pj, dj, arrived[j, :c], tau[j, :c], z_min=z_min)
        for a, b in zip(jax.tree.leaves(jax.tree.map(lambda o: o[j], out)),
                        jax.tree.leaves(legacy_out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


def test_masked_aggregate_stacked_kernel_matches_ref():
    """Pallas kernel path (interpret mode on CPU) == jnp oracle path."""
    rng = np.random.default_rng(3)
    m, s = 2, 4
    params = {"w": jnp.asarray(rng.standard_normal((m, 700)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((m, 10)), jnp.float32)}
    deltas = {"w": jnp.asarray(rng.standard_normal((m, s, 700)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((m, s, 10)), jnp.float32)}
    w = jnp.asarray(rng.random((m, s)) < 0.5, jnp.float32)
    ref = masked_aggregate_stacked(params, deltas, w, use_kernel=False)
    ker = masked_aggregate_stacked(params, deltas, w, use_kernel=True,
                                   tile=256, interpret=True)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(ker)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)
