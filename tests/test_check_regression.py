"""``benchmarks/check_regression.py --entry NAME:REF``: the relative
guard must fail with a *named* error line when the reference row is
missing or timing-less — never a KeyError/ZeroDivisionError traceback —
while absent guarded rows keep skipping cleanly."""
import importlib.util
import json
import os

import pytest

_SCRIPT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "check_regression.py")
_spec = importlib.util.spec_from_file_location("check_regression", _SCRIPT)
check_regression = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_regression)


def _write(path, entries):
    with open(path, "w") as f:
        json.dump(entries, f)
    return str(path)


def _entry(name, us):
    return {"name": name, "us_per_call": us, "derived": ""}


def _run(tmp_path, baseline, current, argv_extra):
    base = _write(tmp_path / "base.json", baseline)
    cur = _write(tmp_path / "cur.json", current)
    return check_regression.main(
        ["--baseline", base, "--current", cur] + argv_extra)


def test_relative_guard_passes(tmp_path, capsys):
    rc = _run(tmp_path,
              [_entry("fused", 10.0), _entry("seq", 100.0)],
              [_entry("fused", 12.0), _entry("seq", 100.0)],
              ["--entry", "fused:seq", "--max-ratio", "1.5"])
    assert rc == 0
    assert "OK" in capsys.readouterr().out


def test_missing_reference_row_fails_with_named_error(tmp_path, capsys):
    """REF absent from the current file while NAME measured fine: a
    misconfigured or broken reference must FAIL loudly, not skip."""
    rc = _run(tmp_path,
              [_entry("fused", 10.0), _entry("seq", 100.0)],
              [_entry("fused", 12.0)],                  # seq row gone
              ["--entry", "fused:seq"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "reference row 'seq' is missing" in out
    assert "FAIL" in out


def test_null_timing_reference_fails_with_named_error(tmp_path, capsys):
    """REF present but ``us_per_call: null`` (an ERROR row): same named
    failure, and never a ZeroDivisionError for ``us_per_call: 0``."""
    for bad_us in (None, 0.0):
        rc = _run(tmp_path,
                  [_entry("fused", 10.0), _entry("seq", 100.0)],
                  [_entry("fused", 12.0), _entry("seq", bad_us)],
                  ["--entry", "fused:seq"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "timing-less" in out and "FAIL" in out


def test_reference_error_in_baseline_file_also_named(tmp_path, capsys):
    rc = _run(tmp_path,
              [_entry("fused", 10.0)],                  # no seq in baseline
              [_entry("fused", 12.0), _entry("seq", 100.0)],
              ["--entry", "fused:seq"])
    out = capsys.readouterr().out
    assert rc == 1 and "baseline file" in out


def test_new_entry_still_skips_cleanly(tmp_path, capsys):
    """A guarded row with no baseline trajectory (and no current row
    either) keeps the historical skip semantics."""
    rc = _run(tmp_path,
              [_entry("other", 5.0)],
              [_entry("other", 6.0)],
              ["--entry", "fused:seq"])
    out = capsys.readouterr().out
    assert rc == 0 and "skipping" in out


def test_reference_row_error_is_a_value_error():
    assert issubclass(check_regression.ReferenceRowError, ValueError)
    with pytest.raises(ValueError):
        check_regression._checked_metric(
            {"a": _entry("a", 1.0)}, "a", "missing-ref", "current")
